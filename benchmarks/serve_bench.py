"""Serving-engine benchmark: the VM-scheduled generation engine vs the
naive sequential per-request loop, on a reduced-config LM.

Two modes:

* ``--arrivals closed`` (default): the seed's closed-loop sweep — every
  lane's request queue is fixed before the single compiled program
  launches; reports tokens/sec vs the sequential oracle.
* ``--arrivals poisson``: open-loop continuous batching — requests arrive
  by a Poisson process at ``--rate`` req/s and are admitted into free
  lanes between VM segments (retire-and-refill); reports p50/p99
  arrival-to-finish latency and lane occupancy, next to a batch-mode
  (all-at-once) run of the same request set for the closed-loop contrast.

``--chaos`` layers fault injection on the poisson stream: a
``--chaos-rate`` fraction of requests carry NaN-poisoning or livelock
sentinel prompts (``tools/chaos.py ChaosModel``) and the engine runs
under ``on_fault="quarantine"`` + ``detect_nonfinite`` + a calibrated
``lane_step_budget`` watchdog, with one retry per faulted request.  The
sweep reports error/retry/shed/timeout rates next to p50/p99, asserts
every request resolves to a terminal ``Completion.status``, and checks
healthy requests' tokens are bit-exact with a chaos-free serve.

``--seed`` makes the Poisson stream reproducible (threaded into the JSON
record).  ``--json PATH`` writes machine-readable records (strict JSON —
NaN is serialized as ``null``).  ``--metrics-out PATH`` shares one
``obs.metrics`` registry across every engine in the sweep and dumps it in
Prometheus text exposition format when the sweep finishes.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.models import get_model
from repro.obs.metrics import MetricsRegistry
from repro.serve.engine import EngineConfig, GenerationEngine, Request

from .common import Table, write_json


def _load_model():
    """Build the bench LM once per sweep (params are sweep-invariant)."""
    cfg = configs.get_smoke_config("smollm-135m")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(cfg, model, params, lanes: int, *, max_new: int,
            prompt_len: int, requests_per_lane: int, mesh,
            segment_steps: int = 64, metrics=None, **fault_knobs):
    ecfg = EngineConfig(
        lanes=lanes, max_context=prompt_len + max_new + 2,
        max_prompt_len=prompt_len, max_new_tokens=max_new,
        requests_per_lane=requests_per_lane, eos_id=0, backend="pc",
        mesh=mesh, segment_steps=segment_steps, **fault_knobs,
    )
    return GenerationEngine(model, params, ecfg, metrics=metrics)


def serve_sweep(lane_counts: list[int], *, max_new: int = 16,
                prompt_len: int = 8, requests_per_lane: int = 2,
                mesh=None) -> tuple[Table, list[dict]]:
    tab = Table(
        "Serve engine — generated tokens/sec (VM engine vs sequential"
        + (f", lanes sharded over {mesh} devices" if mesh else "") + ")",
        ["lanes", "mesh", "vm_tok_s", "seq_tok_s", "speedup", "utilization"],
    )
    nan = float("nan")
    rng = np.random.default_rng(0)
    records: list[dict] = []
    cfg, model, params = _load_model()
    for lanes in lane_counts:
        if mesh and lanes % mesh:
            # Lanes must divide across the mesh: keep the row (as nans)
            # so the gap is visible, matching fig5/fig6.
            tab.add(lanes, mesh, nan, nan, nan, nan)
            records.append({"mode": "closed", "lanes": lanes,
                            "mesh": mesh, "tok_s": None,
                            "skipped": "lanes do not divide across mesh"})
            continue
        eng = _engine(cfg, model, params, lanes, max_new=max_new,
                      prompt_len=prompt_len,
                      requests_per_lane=requests_per_lane, mesh=mesh)
        prompts = rng.integers(
            1, cfg.vocab_size, (lanes, requests_per_lane, prompt_len)
        ).astype(np.int32)
        plens = rng.integers(
            2, prompt_len + 1, (lanes, requests_per_lane)
        ).astype(np.int32)
        res = eng.generate(prompts, plens)  # warm-up (compile)
        t0 = time.perf_counter()
        res = eng.generate(prompts, plens)
        t_vm = time.perf_counter() - t0
        n_tok = int(res["lengths"].sum())
        t0 = time.perf_counter()
        ref = eng.reference_generate(prompts, plens)
        t_seq = time.perf_counter() - t0
        # utilization is None when the engine ran without block stats and
        # can be nan in degenerate runs; show nan in the table but record
        # an explicit null in the JSON (never a bare NaN token).
        util = res["utilization"]
        util_cell = float("nan") if util is None else util
        tab.add(lanes, mesh or 1, n_tok / t_vm, n_tok / t_seq, t_seq / t_vm,
                round(util_cell, 3) if np.isfinite(util_cell) else util_cell)
        records.append({
            "mode": "closed", "lanes": lanes, "mesh": mesh or 1,
            "tok_s": n_tok / t_vm, "seq_tok_s": n_tok / t_seq,
            "utilization": (util if util is not None
                            and np.isfinite(util) else None),
        })
    return tab, records


def poisson_requests(num: int, rate: float, prompt_len: int,
                     vocab: int, seed: int = 0) -> list[Request]:
    """An open-loop arrival stream: exponential gaps at ``rate`` req/s."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=num))
    return [
        Request(
            rid=i,
            prompt=rng.integers(
                1, vocab, int(rng.integers(1, prompt_len + 1))
            ).astype(np.int32),
            arrival=float(t),
        )
        for i, t in enumerate(arrivals)
    ]


def open_loop_sweep(lane_counts: list[int], *, rate: float,
                    num_requests: int, segment_steps: int,
                    max_new: int = 16, prompt_len: int = 8,
                    mesh=None, seed: int = 0,
                    metrics=None) -> tuple[Table, list[dict]]:
    """Open-loop (Poisson) vs batch (all-at-once) continuous serving."""
    tab = Table(
        f"Serve engine, open loop — Poisson arrivals at {rate} req/s vs "
        "all-at-once batch (retire-and-refill in both)",
        ["lanes", "mode", "tok_s", "p50_s", "p99_s", "occupancy",
         "segments"],
    )
    records: list[dict] = []
    cfg, model, params = _load_model()
    for lanes in lane_counts:
        if mesh and lanes % mesh:
            tab.add(lanes, "poisson", *([float("nan")] * 5))
            records.append({"mode": "poisson", "lanes": lanes,
                            "mesh": mesh, "tok_s": None,
                            "skipped": "lanes do not divide across mesh"})
            continue
        eng = _engine(cfg, model, params, lanes, max_new=max_new,
                      prompt_len=prompt_len, requests_per_lane=1,
                      mesh=mesh, segment_steps=segment_steps,
                      metrics=metrics)
        reqs = poisson_requests(num_requests, rate, prompt_len,
                                cfg.vocab_size, seed=seed)
        # Warm-up: compile the stepper path on a tiny closed run.
        eng.serve([Request(rid=0, prompt=np.array([1], np.int32))])
        for mode in ("poisson", "batch"):
            batch = [Request(r.rid, r.prompt, 0.0) for r in reqs] \
                if mode == "batch" else reqs
            comps, stats = eng.serve(batch, segment_steps=segment_steps)
            p50, p99 = stats.p50_latency, stats.p99_latency
            tok_s = stats.generated_tokens / stats.wall_time
            tab.add(lanes, mode, tok_s, p50, p99,
                    round(stats.occupancy, 3), stats.segments)
            records.append({
                "mode": mode, "lanes": lanes, "mesh": mesh or 1,
                "rate": rate if mode == "poisson" else None,
                "seed": seed, "num_requests": num_requests,
                "segment_steps": segment_steps, "tok_s": tok_s,
                "p50_latency_s": p50, "p99_latency_s": p99,
                "occupancy": stats.occupancy, "segments": stats.segments,
                "vm_steps": stats.vm_steps,
            })
    return tab, records


def chaos_requests(num: int, rate: float, chaos_rate: float,
                   prompt_len: int, vocab: int,
                   seed: int) -> tuple[list[Request], dict[int, str]]:
    """A Poisson stream where ``chaos_rate`` of the requests carry fault
    sentinels: ``vocab-1`` = NaN-poison prompt, ``vocab-2`` = livelock
    prompt (alternating).  Returns ``(requests, {rid: fault_kind})``."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=num))
    n_fault = max(int(round(num * chaos_rate)), 2) if chaos_rate else 0
    fault_rids = rng.choice(num, size=min(n_fault, num - 1),
                            replace=False)
    injected = {
        int(rid): ("nonfinite" if i % 2 == 0 else "watchdog")
        for i, rid in enumerate(fault_rids)
    }
    reqs = []
    for i, t in enumerate(arrivals):
        if injected.get(i) == "nonfinite":
            prompt = np.array([vocab - 1], np.int32)
        elif injected.get(i) == "watchdog":
            prompt = np.array([vocab - 2], np.int32)
        else:
            # Healthy prompts avoid the two sentinel ids.
            prompt = rng.integers(
                1, vocab - 2, int(rng.integers(1, prompt_len + 1))
            ).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, arrival=float(t)))
    return reqs, injected


def chaos_sweep(lane_counts: list[int], *, rate: float, chaos_rate: float,
                num_requests: int, segment_steps: int,
                max_new: int = 64, prompt_len: int = 6,
                mesh=None, seed: int = 0,
                metrics=None) -> tuple[Table, list[dict]]:
    """Fault-injected open-loop serving under quarantine.

    Chaos-free serve of the healthy subset first (same rids, same
    arrivals), then the full injected stream through a fresh engine with
    identical knobs — healthy requests must come back bit-exact, every
    request must resolve to a terminal status, and the engine must never
    abort.  Records carry a ``violations`` list; the CLI exits non-zero
    if any cell has one.
    """
    from tools.chaos import ChaosModel

    tab = Table(
        f"Serve engine, chaos — {chaos_rate:.0%} of {rate} req/s poisson "
        "arrivals fault (NaN-poison / livelock), quarantine + watchdog",
        ["lanes", "ok", "faulted", "timeout", "rejected", "retries",
         "p50_s", "p99_s", "occupancy", "bitexact"],
    )
    records: list[dict] = []
    cfg, model, params = _load_model()
    cmodel = ChaosModel(model, eos_pos=prompt_len + 2)
    knobs = dict(on_fault="quarantine", detect_nonfinite=True,
                 max_attempts=2, retry_backoff_s=0.0)

    # Calibrate the watchdog: a healthy request's per-lane executed
    # dispatches are schedule- and batch-independent (a lane only counts
    # dispatches it executes), so one fault-free 1-lane serve measures
    # the healthy path length H.  Healthy lanes need <= H; a livelock
    # lane needs ~ H * max_new / eos_pos >> 2H.  Budget = 2H.
    cal = _engine(cfg, cmodel, params, 1, max_new=max_new,
                  prompt_len=prompt_len, requests_per_lane=1, mesh=None,
                  segment_steps=segment_steps, **knobs)
    _, cal_stats = cal.serve(
        [Request(rid=0, prompt=np.full((prompt_len,), 1, np.int32))]
    )
    budget = 2 * cal_stats.vm_steps
    knobs["lane_step_budget"] = budget

    for lanes in lane_counts:
        if mesh and lanes % mesh:
            tab.add(lanes, *([float("nan")] * 9))
            records.append({"mode": "chaos", "lanes": lanes,
                            "mesh": mesh, "skipped":
                            "lanes do not divide across mesh"})
            continue
        reqs, injected = chaos_requests(
            num_requests, rate, chaos_rate, prompt_len,
            cfg.vocab_size, seed,
        )
        healthy = [r for r in reqs if r.rid not in injected]
        eng = _engine(cfg, cmodel, params, lanes, max_new=max_new,
                      prompt_len=prompt_len, requests_per_lane=1,
                      mesh=mesh, segment_steps=segment_steps,
                      metrics=metrics, **knobs)
        base, _ = eng.serve(healthy)
        base_tokens = {c.rid: c.tokens for c in base}
        comps, stats = eng.serve(reqs)

        violations: list[str] = []
        if {c.rid for c in comps} != {r.rid for r in reqs}:
            violations.append("not every request resolved terminally")
        bad_status = [c.rid for c in comps
                      if c.status not in
                      ("ok", "faulted", "timeout", "rejected")]
        if bad_status:
            violations.append(f"non-terminal statuses at rids "
                              f"{bad_status}")
        not_contained = [c.rid for c in comps
                         if c.rid in injected and c.status == "ok"]
        if not_contained:
            violations.append(
                f"injected requests completed 'ok': {not_contained}"
            )
        bitexact = True
        for c in comps:
            if c.rid in injected or c.status != "ok":
                continue
            if not np.array_equal(c.tokens, base_tokens[c.rid]):
                bitexact = False
                violations.append(
                    f"healthy rid {c.rid} tokens diverged from "
                    "chaos-free run"
                )
                break
        p50, p99 = stats.p50_latency, stats.p99_latency
        n = len(reqs)
        tab.add(lanes, stats.ok, stats.faulted, stats.timeout,
                stats.rejected, stats.retries, p50, p99,
                round(stats.occupancy, 3), bitexact)
        records.append({
            "mode": "chaos", "lanes": lanes, "mesh": mesh or 1,
            "seed": seed, "rate": rate, "chaos_rate": chaos_rate,
            "num_requests": n, "segment_steps": segment_steps,
            "lane_step_budget": budget,
            "injected": {k: sum(1 for v in injected.values() if v == k)
                         for k in ("nonfinite", "watchdog")},
            "statuses": {"ok": stats.ok, "faulted": stats.faulted,
                         "timeout": stats.timeout,
                         "rejected": stats.rejected},
            "error_rate": stats.faulted / n,
            "retry_rate": stats.retries / n,
            "shed_rate": stats.rejected / n,
            "timeout_rate": stats.timeout / n,
            "retries": stats.retries,
            "p50_latency_s": p50, "p99_latency_s": p99,
            "occupancy": stats.occupancy,
            "healthy_bitexact": bitexact,
            "violations": violations,
        })
    return tab, records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--lanes", default="2,8")
    ap.add_argument("--mesh", default="none",
                    help="shard lanes over this many devices ('none' = "
                         "unsharded; lanes must divide across the mesh)")
    ap.add_argument("--arrivals", default="closed",
                    choices=("closed", "poisson"),
                    help="closed = pre-assigned queues (seed baseline); "
                         "poisson = open-loop continuous batching")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="poisson arrival rate, requests/sec")
    ap.add_argument("--num-requests", type=int, default=32,
                    help="poisson mode: total requests in the stream")
    ap.add_argument("--segment-steps", type=int, default=64,
                    help="VM dispatches per segment between host "
                         "admission/retire checks")
    ap.add_argument("--seed", type=int, default=0,
                    help="poisson/chaos arrival-stream seed "
                         "(reproducible CI smokes)")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-injection sweep: poisson arrivals where "
                         "--chaos-rate of the requests NaN-poison or "
                         "livelock their lane (quarantine + watchdog)")
    ap.add_argument("--chaos-rate", type=float, default=0.2,
                    help="fraction of chaos requests that fault")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable records (strict JSON)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the sweep's shared serve-metrics registry "
                         "in Prometheus text exposition format")
    args = ap.parse_args(argv)
    lanes = [int(x) for x in args.lanes.split(",")]
    mesh = None if args.mesh.lower() in ("none", "0") else int(args.mesh)
    metrics = MetricsRegistry() if args.metrics_out else None
    if args.chaos:
        tab, records = chaos_sweep(
            lanes, rate=args.rate, chaos_rate=args.chaos_rate,
            num_requests=args.num_requests,
            segment_steps=args.segment_steps, mesh=mesh, seed=args.seed,
            metrics=metrics,
        )
    elif args.arrivals == "poisson":
        tab, records = open_loop_sweep(
            lanes, rate=args.rate, num_requests=args.num_requests,
            segment_steps=args.segment_steps, mesh=mesh, seed=args.seed,
            metrics=metrics,
        )
    else:
        tab, records = serve_sweep(lanes, mesh=mesh)
    print(tab.render())
    if args.metrics_out:
        if metrics is None or not metrics.render_prometheus().strip():
            print("[--metrics-out: closed-loop sweep records no serve "
                  "metrics]")
        with open(args.metrics_out, "w") as f:
            f.write((metrics or MetricsRegistry()).render_prometheus())
        print(f"[wrote {args.metrics_out}]")
    if args.json:
        write_json(args.json, {
            "benchmark": "serve_bench",
            "config": {"arrivals": args.arrivals, "lanes": lanes,
                       "mesh": mesh, "rate": args.rate,
                       "seed": args.seed, "chaos": args.chaos,
                       "chaos_rate": args.chaos_rate if args.chaos
                       else None,
                       "num_requests": args.num_requests,
                       "segment_steps": args.segment_steps},
            "records": records,
        })
        print(f"[wrote {args.json}: {len(records)} records]")
    violations = [v for r in records for v in r.get("violations", [])]
    for v in violations:
        print(f"[VIOLATION] {v}")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
