"""Roofline report: reads the dry-run JSON artifacts and renders the
per-(arch x shape x mesh) table of compute / memory / collective terms,
dominant bottleneck, useful-FLOPs ratio and roofline fraction.

Artifacts are produced by::

    python -m repro.launch.dryrun --arch A --shape S [--multi-pod] --out \
        benchmarks/artifacts/<arch>__<shape>__<mesh>.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from .common import Table

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def load_artifacts(directory: str = ARTIFACT_DIR) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            data = json.load(f)
        cells.extend(data if isinstance(data, list) else [data])
    return cells


def render(cells: list[dict], mesh: str = "16x16") -> str:
    tab = Table(
        f"Roofline terms per (arch x shape), mesh {mesh} "
        "(seconds per step, per chip; *_fl = with Pallas flash attention "
        "modeled)",
        ["arch", "shape", "t_comp", "t_mem", "t_coll", "bound",
         "useful", "roof", "t_mem_fl", "roof_fl", "peakGB", "mb"],
    )
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        if c["mesh"] != mesh or "error" in c:
            continue
        tab.add(
            c["arch"], c["shape"],
            c["t_compute"], c["t_memory"], c["t_collective"],
            c["bottleneck"],
            round(c.get("useful_flops_ratio", 0.0), 3),
            round(c.get("roofline_fraction", 0.0), 4),
            round(c["t_memory_flash"], 3) if "t_memory_flash" in c else "-",
            round(c["roofline_fraction_flash"], 4)
            if "roofline_fraction_flash" in c else "-",
            round(c.get("peak_bytes", 0) / 1e9, 2),
            c.get("microbatches", 1),
        )
    failed = [c for c in cells if c.get("mesh") == mesh and "error" in c]
    out = tab.render()
    if failed:
        out += "\nFAILED cells: " + ", ".join(
            f"{c['arch']}x{c['shape']}" for c in failed
        )
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=ARTIFACT_DIR)
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)
    cells = load_artifacts(args.dir)
    if not cells:
        print(f"(no dry-run artifacts in {args.dir} — run "
              "python -m repro.launch.dryrun first)")
        return 0
    print(render(cells, args.mesh))
    return 0


if __name__ == "__main__":
    sys.exit(main())
