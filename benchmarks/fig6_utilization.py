"""Paper Figure 6: batch utilization of the gradient computation on the
correlated Gaussian, PC autobatching vs local static autobatching.

Utilization(tag=grad) = active member-gradient evaluations /
(gradient launches x batch size).  Local static autobatching must
synchronize chains on *trajectory* boundaries (its Python recursion pins
every member to the same call stack), while the PC VM batches gradients
across trajectory AND recursion-depth boundaries — the paper's headline
utilization win (~2x at 10 trajectories).

The pc arm expands into one column per ``--schedule`` x ``--fuse`` x
``--mesh`` x ``--compact-every`` x ``--use-kernel`` combination, so the
occupancy effect of the VM scheduler, superblock fusion and lane
compaction is visible next to the local-static baseline.
"""
from __future__ import annotations

import argparse
import sys

from repro.mcmc import nuts, targets

from .common import Table

from .fig5_throughput import DEFAULT_PC_VARIANTS, parse_pc_variants, pc_arm_name


def utilization_sweep(
    batch_sizes: list[int],
    *,
    dim: int = 100,
    rho: float = 0.95,
    num_steps: int = 10,
    max_tree_depth: int = 8,
    steps_per_leaf: int = 4,
    eps: float = 0.1,
    pc_variants: tuple = DEFAULT_PC_VARIANTS,
) -> Table:
    target = targets.correlated_gaussian(dim=dim, rho=rho)
    settings = nuts.NutsSettings(
        max_tree_depth=max_tree_depth, num_steps=num_steps,
        steps_per_leaf=steps_per_leaf,
    )
    solo = len(pc_variants) == 1
    # Back-compat: 3-tuple variants mean no compaction / kernel.
    pc_variants = tuple((*v, None, False)[:5] for v in pc_variants)
    pc_cols = [
        pc_arm_name(sched, fz, mesh, ce, uk, solo=solo)
        for sched, fz, mesh, ce, uk in pc_variants
    ]
    tab = Table(
        f"Fig 6 — batch utilization of gradient evals "
        f"(correlated Gaussian d={dim} rho={rho}, {num_steps} trajectories)",
        ["batch", *pc_cols, "local_static", f"{pc_cols[0]}/local"],
    )
    # One kernel per arm across the sweep; each pc lowering is shared and
    # only the per-batch-size executors differ.
    pcs = [
        nuts.make_nuts_kernel(target, settings, backend="pc",
                              schedule=sched, fuse=fz, mesh=mesh,
                              compact_every=ce, use_kernel=uk)
        for sched, fz, mesh, ce, uk in pc_variants
    ]
    loc = nuts.make_nuts_kernel(target, settings, backend="local")
    for z in batch_sizes:
        theta0, eps_arg, keys = nuts.initial_state(target, z, eps=eps, seed=0)
        u_pcs = []
        for pc, (_, _, mesh, _, _) in zip(pcs, pc_variants):
            ndev = getattr(mesh, "size", mesh) or 1
            if mesh is not None and z % ndev:
                # Batch doesn't divide across this arm's mesh: nan the
                # cell instead of aborting the sweep.
                u_pcs.append(float("nan"))
                continue
            pc(theta0, eps_arg, keys)
            u_pcs.append(_grad_util(pc))
        loc(theta0, eps_arg, keys)
        u_loc = _grad_util(loc)
        tab.add(z, *u_pcs, u_loc,
                u_pcs[0] / u_loc if u_loc else float("nan"))
    return tab


def _grad_util(kernel) -> float:
    """The kernel's gradient-tag utilization, failing loudly when absent.

    ``utilization`` is ``{}``/missing the tag when the kernel ran with
    ``collect_stats=False`` — the old ``["grad"]`` lookup would KeyError
    and a ``.get`` default would silently plot nan as data; this figure
    IS the utilization measurement, so demand the stats instead.
    """
    u = kernel.utilization.get("grad")
    if u is None:
        raise RuntimeError(
            "fig6 needs block statistics: build the NUTS kernel with "
            "collect_stats=True (the default) so utilization['grad'] "
            "is recorded"
        )
    return u


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (d=100, batches up to 64)")
    ap.add_argument("--batches", default=None)
    ap.add_argument("--schedule", default="earliest",
                    help="comma list of pc schedules "
                         "(earliest, popular, sweep, lookahead)")
    ap.add_argument("--fuse", default="on",
                    help="comma list of on/off: superblock fusion settings "
                         "for the pc arm")
    ap.add_argument("--mesh", default="none",
                    help="comma list of lane-sharding device counts for the "
                         "pc arm ('none' = unsharded)")
    ap.add_argument("--compact-every", default="none",
                    help="comma list of lane-compaction cadences for the pc "
                         "arm ('none' = no compaction)")
    ap.add_argument("--use-kernel", default="off",
                    help="comma list of on/off: Pallas stack kernels for "
                         "the pc arm")
    args = ap.parse_args(argv)
    if args.full:
        batches = [1, 2, 4, 8, 16, 32, 64]
        kw: dict = dict(dim=100, num_steps=10, max_tree_depth=10)
    else:
        batches = [1, 4, 16, 32]
        kw = dict(dim=16, num_steps=6, max_tree_depth=7)
    if args.batches:
        batches = [int(b) for b in args.batches.split(",")]
    pc_variants = parse_pc_variants(args.schedule, args.fuse, args.mesh,
                                    args.compact_every, args.use_kernel)
    print(utilization_sweep(batches, pc_variants=pc_variants, **kw).render())
    return 0


if __name__ == "__main__":
    sys.exit(main())
