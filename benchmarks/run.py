"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper table/figure (quick CPU configurations;
pass ``--full`` for paper-scale) plus the framework-level benches, and
renders the roofline table from any dry-run artifacts present.

  fig5   NUTS gradient throughput vs batch size (paper Fig. 5)
  fig6   batch utilization across recursion (paper Fig. 6)
  serve  VM-scheduled generation engine throughput
  roofline  per-(arch x shape x mesh) terms from dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import time

from . import fig5_throughput, fig6_utilization, roofline, serve_bench


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,fig6,serve,roofline")
    ap.add_argument("--batches", default=None,
                    help="comma-separated batch sizes for fig5/fig6")
    ap.add_argument("--mesh", default=None,
                    help="comma list of lane-sharding device counts for the "
                         "fig5/serve pc arms (e.g. 'none,8'; requires that "
                         "many visible devices)")
    ap.add_argument("--per-device-batch", action="store_true",
                    help="fig5: treat --batches as per-device (mesh arms "
                         "scale total batch by device count)")
    ap.add_argument("--json-out", default="BENCH_fig5.json",
                    help="path for the machine-readable fig5 results "
                         "(tracked across PRs); empty string disables")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    common = (["--full"] if args.full else []) + (
        ["--batches", args.batches] if args.batches else []
    )
    t0 = time.time()
    if want("fig5"):
        print()
        # Measure the fused pc arm against the unfused/earliest seed
        # baseline in the same run, and persist the records.
        fig5_args = common + ["--fuse", "on,off"]
        if args.mesh:
            fig5_args += ["--mesh", args.mesh]
            if args.per_device_batch:
                fig5_args += ["--per-device-batch"]
        if args.json_out:
            fig5_args += ["--json", args.json_out]
        fig5_throughput.main(fig5_args)
    if want("fig6"):
        print()
        fig6_utilization.main(common)
    if want("serve"):
        print()
        serve_args = []
        if args.mesh:
            # serve_bench takes a single device count: use the largest.
            counts = [m for m in args.mesh.split(",")
                      if m.strip().lower() not in ("none", "0")]
            if counts:
                serve_args = ["--mesh", max(counts, key=int)]
        serve_bench.main(serve_args)
    if want("roofline"):
        print()
        roofline.main([])
        print()
        roofline.main(["--mesh", "2x16x16"])
    print(f"\n[benchmarks done in {time.time() - t0:.1f}s]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
