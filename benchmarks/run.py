"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper table/figure (quick CPU configurations;
pass ``--full`` for paper-scale) plus the framework-level benches, and
renders the roofline table from any dry-run artifacts present.

  fig5   NUTS gradient throughput vs batch size (paper Fig. 5)
  fig6   batch utilization across recursion (paper Fig. 6)
  serve  VM-scheduled generation engine throughput
  roofline  per-(arch x shape x mesh) terms from dry-run artifacts
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from pathlib import Path

from . import fig5_throughput, fig6_utilization, roofline, serve_bench
from .common import validate_bench_json

#: Default BENCH_*.json artifacts land at the repo root regardless of
#: the invoking cwd, so the perf-trajectory records tracked across PRs
#: always live in one place.
REPO_ROOT = Path(__file__).resolve().parent.parent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,fig6,serve,roofline")
    ap.add_argument("--batches", default=None,
                    help="comma-separated batch sizes for fig5/fig6")
    ap.add_argument("--mesh", default=None,
                    help="comma list of lane-sharding device counts for the "
                         "fig5/serve pc arms (e.g. 'none,8'; requires that "
                         "many visible devices)")
    ap.add_argument("--schedule", default=None,
                    help="comma list of pc schedules for fig5 (earliest, "
                         "popular, sweep, lookahead); default earliest")
    ap.add_argument("--compact-every", default=None,
                    help="comma list of lane-compaction cadences for the "
                         "fig5 pc arms (e.g. 'none,1')")
    ap.add_argument("--use-kernel", default=None,
                    help="comma list of on/off: Pallas stack kernels for "
                         "the fig5 pc arms")
    ap.add_argument("--pgo", default=None,
                    help="comma list of on/off: profile-guided re-lowering "
                         "for the fig5 pc arms (e.g. 'on,off')")
    ap.add_argument("--per-device-batch", action="store_true",
                    help="fig5: treat --batches as per-device (mesh arms "
                         "scale total batch by device count)")
    ap.add_argument("--serve-arrivals", default="closed",
                    choices=("closed", "poisson"),
                    help="serve bench mode: closed-loop sweep or open-loop "
                         "Poisson continuous batching")
    ap.add_argument("--serve-requests", type=int, default=16,
                    help="open-loop serve: requests in the arrival stream")
    ap.add_argument("--json-out", default=str(REPO_ROOT / "BENCH_fig5.json"),
                    help="path for the machine-readable fig5 results "
                         "(tracked across PRs; default: repo root); empty "
                         "string disables")
    ap.add_argument("--serve-json-out",
                    default=str(REPO_ROOT / "BENCH_serve.json"),
                    help="path for the machine-readable serve results "
                         "(default: repo root); empty string disables")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    common = (["--full"] if args.full else []) + (
        ["--batches", args.batches] if args.batches else []
    )
    emitted: list[str] = []  # artifacts THIS run wrote (validated below)
    t0 = time.time()
    if want("fig5"):
        print()
        # Measure the fused pc arm against the unfused/earliest seed
        # baseline in the same run, and persist the records.
        fig5_args = common + ["--fuse", "on,off"]
        if args.schedule:
            fig5_args += ["--schedule", args.schedule]
        if args.compact_every:
            fig5_args += ["--compact-every", args.compact_every]
        if args.use_kernel:
            fig5_args += ["--use-kernel", args.use_kernel]
        if args.pgo:
            fig5_args += ["--pgo", args.pgo]
        if args.mesh:
            fig5_args += ["--mesh", args.mesh]
            if args.per_device_batch:
                fig5_args += ["--per-device-batch"]
        if args.json_out:
            fig5_args += ["--json", args.json_out]
            emitted.append(args.json_out)
        fig5_throughput.main(fig5_args)
    if want("fig6"):
        print()
        fig6_utilization.main(common)
    if want("serve"):
        print()
        serve_args = ["--arrivals", args.serve_arrivals]
        if args.serve_arrivals == "poisson":
            serve_args += ["--num-requests", str(args.serve_requests)]
        if args.mesh:
            # serve_bench takes a single device count: use the largest.
            counts = [m for m in args.mesh.split(",")
                      if m.strip().lower() not in ("none", "0")]
            if counts:
                serve_args += ["--mesh", max(counts, key=int)]
        if args.serve_json_out:
            serve_args += ["--json", args.serve_json_out]
            emitted.append(args.serve_json_out)
        serve_bench.main(serve_args)
    if want("roofline"):
        print()
        roofline.main([])
        print()
        roofline.main(["--mesh", "2x16x16"])
    # Every artifact this run emitted must parse under *strict* JSON
    # (json.dump with allow_nan=False upstream; a bare NaN/Infinity here
    # fails CI instead of poisoning the perf-trajectory records).  Only
    # files this run wrote are checked — a stale pre-existing artifact
    # must not fail an unrelated run.
    artifacts = sorted(p for p in emitted if os.path.exists(p))
    if artifacts:
        validate_bench_json(artifacts)
        print(f"[validated strict JSON: {', '.join(artifacts)}]")
    print(f"\n[benchmarks done in {time.time() - t0:.1f}s]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
