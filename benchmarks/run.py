"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper table/figure (quick CPU configurations;
pass ``--full`` for paper-scale) plus the framework-level benches, and
renders the roofline table from any dry-run artifacts present.

  fig5   NUTS gradient throughput vs batch size (paper Fig. 5)
  fig6   batch utilization across recursion (paper Fig. 6)
  serve  VM-scheduled generation engine throughput
  roofline  per-(arch x shape x mesh) terms from dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import time

from . import fig5_throughput, fig6_utilization, roofline, serve_bench


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,fig6,serve,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    t0 = time.time()
    if want("fig5"):
        print()
        fig5_throughput.main(["--full"] if args.full else [])
    if want("fig6"):
        print()
        fig6_utilization.main(["--full"] if args.full else [])
    if want("serve"):
        print()
        serve_bench.main([])
    if want("roofline"):
        print()
        roofline.main([])
        print()
        roofline.main(["--mesh", "2x16x16"])
    print(f"\n[benchmarks done in {time.time() - t0:.1f}s]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
