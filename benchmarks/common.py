"""Shared benchmark utilities: timing, result tables, strict JSON I/O."""
from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable


def json_sanitize(obj: Any) -> Any:
    """Replace non-finite floats with ``None``, recursively.

    ``json.dump`` would otherwise emit bare ``NaN``/``Infinity`` tokens,
    which are not JSON and break strict parsers downstream.
    """
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, dict):
        return {k: json_sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_sanitize(v) for v in obj]
    return obj


def write_json(path: str, payload: Any) -> None:
    """Write a ``BENCH_*.json`` artifact as *strict* JSON.

    Non-finite floats become ``null`` and ``allow_nan=False`` guarantees
    nothing non-strict can ever sneak into the file (CI parses every
    emitted artifact with a strict parser — see ``validate_bench_json``).
    """
    with open(path, "w") as f:
        json.dump(json_sanitize(payload), f, indent=2, allow_nan=False)


def _reject_constant(name: str) -> float:
    raise ValueError(f"non-strict JSON constant {name!r}")


def validate_bench_json(paths: list[str]) -> None:
    """Strict-parse benchmark artifacts; raise on NaN/Infinity tokens."""
    for p in paths:
        with open(p) as f:
            json.load(f, parse_constant=_reject_constant)


def best_of(fn: Callable[[], None], repeats: int = 3) -> float:
    """Best wall time of ``repeats`` runs (paper: best of five warm runs)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


@dataclass
class Table:
    title: str
    columns: list
    rows: list = field(default_factory=list)

    def add(self, *row):
        self.rows.append(row)

    def render(self) -> str:
        widths = [
            max(len(str(c)), *(len(_fmt(r[i])) for r in self.rows))
            if self.rows else len(str(c))
            for i, c in enumerate(self.columns)
        ]
        lines = [self.title, "-" * len(self.title)]
        lines.append("  ".join(
            str(c).ljust(w) for c, w in zip(self.columns, widths)
        ))
        for r in self.rows:
            lines.append("  ".join(
                _fmt(v).ljust(w) for v, w in zip(r, widths)
            ))
        return "\n".join(lines)


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)
