"""Shared benchmark utilities: timing, result tables."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable


def best_of(fn: Callable[[], None], repeats: int = 3) -> float:
    """Best wall time of ``repeats`` runs (paper: best of five warm runs)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


@dataclass
class Table:
    title: str
    columns: list
    rows: list = field(default_factory=list)

    def add(self, *row):
        self.rows.append(row)

    def render(self) -> str:
        widths = [
            max(len(str(c)), *(len(_fmt(r[i])) for r in self.rows))
            if self.rows else len(str(c))
            for i, c in enumerate(self.columns)
        ]
        lines = [self.title, "-" * len(self.title)]
        lines.append("  ".join(
            str(c).ljust(w) for c, w in zip(self.columns, widths)
        ))
        for r in self.rows:
            lines.append("  ".join(
                _fmt(v).ljust(w) for v, w in zip(r, widths)
            ))
        return "\n".join(lines)


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)
