"""Paper Figure 5: NUTS gradient-evaluation throughput vs batch size.

Arms (matching the paper's, adapted to JAX per DESIGN.md §2):

* ``pc``          — program-counter autobatching, whole chain compiled
                    end-to-end with XLA (the paper's headline arm);
* ``local``       — local static autobatching, host-Python control with
                    XLA-compiled basic blocks (the paper's "hybrid" arm);
* ``local_eager`` — local static autobatching, op-by-op dispatch (the
                    paper's "eager" arm);
* ``unbatched``   — one chain at a time through the reference
                    interpreter (the paper's unbatched-eager baseline);
* ``iterative``   — hand-rewritten iterative NUTS (vmap+jit), the
                    expert-manual-effort ceiling the paper cites.

Throughput = member gradient evaluations per second (leaf executions x
active members x grads-per-leaf / wall time), best of ``repeats`` warm
runs, compilation excluded — the paper's methodology.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.core import api
from repro.mcmc import iterative, nuts, targets

from .common import Table, best_of


def throughput_sweep(
    batch_sizes: list[int],
    *,
    num_data: int = 2_000,
    dim: int = 50,
    num_steps: int = 3,
    max_tree_depth: int = 6,
    steps_per_leaf: int = 4,
    eps: float = 0.02,
    repeats: int = 3,
    arms: tuple = ("pc", "local", "local_eager", "unbatched", "iterative"),
    unbatched_cap: int = 8,
) -> Table:
    target = targets.logistic_regression(num_data=num_data, dim=dim)
    settings = nuts.NutsSettings(
        max_tree_depth=max_tree_depth, num_steps=num_steps,
        steps_per_leaf=steps_per_leaf,
    )
    prog = nuts.build_nuts_program(target, settings)
    gpl = settings.grads_per_leaf
    tab = Table(
        f"Fig 5 — NUTS grad evals/sec "
        f"(logreg n={num_data} d={dim}, {num_steps} steps/chain)",
        ["batch", *arms],
    )

    for z in batch_sizes:
        inputs = nuts.initial_state(target, z, eps=eps, seed=0)
        row = [z]
        for arm in arms:
            if arm == "iterative":
                run = iterative.make_batched(target, settings)
                out = run(inputs["theta0"], inputs["eps"], inputs["key"])
                grads = int(out["grads"].sum())  # warm-up/compile above
                t = best_of(lambda: jax.block_until_ready(
                    run(inputs["theta0"], inputs["eps"], inputs["key"])
                    ["theta"]
                ), repeats)
                row.append(grads / t)
                continue
            if arm == "unbatched":
                if z > unbatched_cap:
                    row.append(float("nan"))
                    continue
                bp = api.autobatch(prog, z, backend="reference")
                # count grads via a pc run (same trajectories in expectation)
                cnt = api.autobatch(
                    prog, z, backend="pc",
                    max_depth=nuts.recommended_max_depth(settings),
                    max_steps=500_000,
                )
                cnt(inputs)
                execs, active = cnt.last_result.tag_stats["grad"]
                t = best_of(lambda: bp(inputs), 1)
                row.append(active * gpl / t)
                continue
            backend = arm
            bp = api.autobatch(
                prog, z, backend=backend,
                max_depth=nuts.recommended_max_depth(settings),
                max_steps=500_000,
            )
            bp(inputs)  # warm-up (compile)
            if backend == "pc":
                execs, active = bp.last_result.tag_stats["grad"]
            else:
                execs = bp.batcher.stats.tag_execs["grad"]
                active = bp.batcher.stats.tag_active["grad"]
            t = best_of(lambda: bp(inputs), repeats)
            row.append(active * gpl / t)
        tab.add(*row)
    return tab


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale problem (10k x 100 logreg)")
    ap.add_argument("--batches", default=None,
                    help="comma-separated batch sizes")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    if args.full:
        kw: dict = dict(num_data=10_000, dim=100, max_tree_depth=10,
                        num_steps=10)
        batches = [1, 4, 16, 64, 256, 1024]
    else:
        kw = {}
        batches = [1, 4, 16, 64]
    if args.batches:
        batches = [int(b) for b in args.batches.split(",")]
    tab = throughput_sweep(batches, repeats=args.repeats, **kw)
    print(tab.render())
    return 0


if __name__ == "__main__":
    sys.exit(main())
