"""Paper Figure 5: NUTS gradient-evaluation throughput vs batch size.

Arms (matching the paper's, adapted to JAX per DESIGN.md §2):

* ``pc``          — program-counter autobatching, whole chain compiled
                    end-to-end with XLA (the paper's headline arm);
* ``local``       — local static autobatching, host-Python control with
                    XLA-compiled basic blocks (the paper's "hybrid" arm);
* ``local_eager`` — local static autobatching, op-by-op dispatch (the
                    paper's "eager" arm);
* ``unbatched``   — one chain at a time through the reference
                    interpreter (the paper's unbatched-eager baseline);
* ``iterative``   — hand-rewritten iterative NUTS (vmap+jit), the
                    expert-manual-effort ceiling the paper cites.

Throughput = member gradient evaluations per second (leaf executions x
active members x grads-per-leaf / wall time), best of ``repeats`` warm
runs, compilation excluded — the paper's methodology.
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.mcmc import iterative, nuts, targets

from .common import Table, best_of


def throughput_sweep(
    batch_sizes: list[int],
    *,
    num_data: int = 2_000,
    dim: int = 50,
    num_steps: int = 3,
    max_tree_depth: int = 6,
    steps_per_leaf: int = 4,
    eps: float = 0.02,
    repeats: int = 3,
    arms: tuple = ("pc", "local", "local_eager", "unbatched", "iterative"),
    unbatched_cap: int = 8,
) -> Table:
    target = targets.logistic_regression(num_data=num_data, dim=dim)
    settings = nuts.NutsSettings(
        max_tree_depth=max_tree_depth, num_steps=num_steps,
        steps_per_leaf=steps_per_leaf,
    )
    gpl = settings.grads_per_leaf
    tab = Table(
        f"Fig 5 — NUTS grad evals/sec "
        f"(logreg n={num_data} d={dim}, {num_steps} steps/chain)",
        ["batch", *arms],
    )
    # One kernel per backend arm: the trace and (for pc) the stack-explicit
    # lowering are built once and shared across every batch size in the
    # sweep — only the per-batch-size executors are (re)compiled.
    kernels = {
        arm: nuts.make_nuts_kernel(
            target, settings, backend=arm, max_steps=500_000
        )
        for arm in arms
        if arm in ("pc", "local", "local_eager")
    }
    counter = None
    if "unbatched" in arms:
        kernels["unbatched"] = nuts.make_nuts_kernel(
            target, settings, backend="reference"
        )
        # Grad counter for the unbatched arm (same trajectories in
        # expectation): reuse the pc kernel when it is in the sweep anyway.
        counter = kernels.get("pc") or nuts.make_nuts_kernel(
            target, settings, max_steps=500_000
        )

    for z in batch_sizes:
        theta0, eps_arg, keys = nuts.initial_state(target, z, eps=eps, seed=0)
        row = [z]
        for arm in arms:
            if arm == "iterative":
                run = iterative.make_batched(target, settings)
                out = run(theta0, eps_arg, keys)
                grads = int(out["grads"].sum())  # warm-up/compile above
                t = best_of(lambda: jax.block_until_ready(
                    run(theta0, eps_arg, keys)["theta"]
                ), repeats)
                row.append(grads / t)
                continue
            if arm == "unbatched":
                if z > unbatched_cap:
                    row.append(float("nan"))
                    continue
                counter(theta0, eps_arg, keys)
                execs, active = counter.tag_stats["grad"]
                ref = kernels["unbatched"]
                t = best_of(lambda: ref(theta0, eps_arg, keys), 1)
                row.append(active * gpl / t)
                continue
            kern = kernels[arm]
            kern(theta0, eps_arg, keys)  # warm-up (compile)
            execs, active = kern.tag_stats["grad"]
            t = best_of(lambda: kern(theta0, eps_arg, keys), repeats)
            row.append(active * gpl / t)
        tab.add(*row)
    return tab


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale problem (10k x 100 logreg)")
    ap.add_argument("--batches", default=None,
                    help="comma-separated batch sizes")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    if args.full:
        kw: dict = dict(num_data=10_000, dim=100, max_tree_depth=10,
                        num_steps=10)
        batches = [1, 4, 16, 64, 256, 1024]
    else:
        kw = {}
        batches = [1, 4, 16, 64]
    if args.batches:
        batches = [int(b) for b in args.batches.split(",")]
    tab = throughput_sweep(batches, repeats=args.repeats, **kw)
    print(tab.render())
    return 0


if __name__ == "__main__":
    sys.exit(main())
