"""Paper Figure 5: NUTS gradient-evaluation throughput vs batch size.

Arms (matching the paper's, adapted to JAX per DESIGN.md §2):

* ``pc``          — program-counter autobatching, whole chain compiled
                    end-to-end with XLA (the paper's headline arm);
* ``local``       — local static autobatching, host-Python control with
                    XLA-compiled basic blocks (the paper's "hybrid" arm);
* ``local_eager`` — local static autobatching, op-by-op dispatch (the
                    paper's "eager" arm);
* ``unbatched``   — one chain at a time through the reference
                    interpreter (the paper's unbatched-eager baseline);
* ``iterative``   — hand-rewritten iterative NUTS (vmap+jit), the
                    expert-manual-effort ceiling the paper cites.

The ``pc`` arm expands into one column per ``--schedule`` x ``--fuse`` x
``--mesh`` x ``--compact-every`` x ``--use-kernel`` x ``--pgo``
combination (e.g.
``--schedule earliest,popular --fuse on,off --mesh none,8
--compact-every none,1``), so the dispatch-overhead win of superblock
fusion / occupancy scheduling, the multi-device scaling of lane sharding,
and the tile-occupancy recovery of lane compaction are *measured in the
same run* as the seed baseline rather than asserted.  Each pc record
carries ``mean_occupancy`` (tile-based SIMD occupancy) and
``mean_lane_occupancy`` (whole-batch) so the two effects are separable.

``--mesh`` values are device counts (``none`` = unsharded single-device);
on CPU, fake a mesh with ``XLA_FLAGS=--xla_force_host_platform_device_count
=8``.  With ``--per-device-batch``, ``--batches`` values are *per-device*
batch sizes: a ``mesh=8`` arm at batch 32 runs 256 total lanes — the
fixed-work-per-device (weak-scaling) reading of Fig. 5.

Throughput = member gradient evaluations per second (leaf executions x
active members x grads-per-leaf / wall time), best of ``repeats`` warm
runs, compilation excluded — the paper's methodology.

``--json PATH`` additionally writes the machine-readable records
(arm x batch -> grads/sec plus schedule/fuse/mesh metadata) so the perf
trajectory is tracked across PRs (see benchmarks/run.py).
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.mcmc import iterative, nuts, targets

from .common import Table, best_of, write_json

#: (schedule, fuse, mesh, compact_every, use_kernel, pgo) combinations the
#: plain "pc" arm expands into (mesh=None means unsharded single-device
#: execution; compact_every=None means no lane compaction; pgo=True
#: re-lowers through the profile-guided pipeline from a trace collected
#: at setup time).
DEFAULT_PC_VARIANTS = (("earliest", True, None, None, False, False),)

#: Trace-ring capacity for the setup-time profiling run of a pgo variant
#: (large enough that the profile covers the whole run at the profiling
#: batch; dropped early events would skew hotness toward late blocks).
PGO_TRACE_CAPACITY = 262_144


def pc_arm_name(schedule: str, fuse: bool, mesh, compact_every=None,
                use_kernel: bool = False, pgo: bool = False,
                *, solo: bool) -> str:
    if solo:
        return "pc"
    parts = [schedule, "fuse" if fuse else "nofuse"]
    if mesh is not None:
        parts.append(f"mesh{getattr(mesh, 'size', mesh)}")
    if compact_every is not None:
        parts.append(f"ce{compact_every}")
    if use_kernel:
        parts.append("kernel")
    if pgo:
        parts.append("pgo")
    return f"pc[{','.join(parts)}]"


def throughput_sweep(
    batch_sizes: list[int],
    *,
    num_data: int = 2_000,
    dim: int = 50,
    num_steps: int = 3,
    max_tree_depth: int = 6,
    steps_per_leaf: int = 4,
    eps: float = 0.02,
    repeats: int = 3,
    arms: tuple = ("pc", "local", "local_eager", "unbatched", "iterative"),
    pc_variants: tuple = DEFAULT_PC_VARIANTS,
    unbatched_cap: int = 8,
    per_device_batch: bool = False,
    verify: bool = False,
) -> tuple[Table, list[dict]]:
    """Run the sweep; returns the rendered table and JSON-able records."""
    target = targets.logistic_regression(num_data=num_data, dim=dim)
    settings = nuts.NutsSettings(
        max_tree_depth=max_tree_depth, num_steps=num_steps,
        steps_per_leaf=steps_per_leaf,
    )
    gpl = settings.grads_per_leaf

    # Expand the "pc" arm into one column per
    # (schedule, fuse, mesh, compact_every, use_kernel, pgo) variant.
    solo = len(pc_variants) == 1
    columns: list[str] = []
    pc_meta: dict[str, tuple] = {}
    _defaults = (None, False, False)  # (compact_every, use_kernel, pgo)
    for arm in arms:
        if arm == "pc":
            for variant in pc_variants:
                # Back-compat: 3-tuples from older callers mean
                # (schedule, fuse, mesh) with no compaction/kernel/pgo.
                v = tuple(variant) + _defaults[len(variant) - 3:]
                sched, fz, mesh, ce, uk, pg = v
                name = pc_arm_name(sched, fz, mesh, ce, uk, pg, solo=solo)
                columns.append(name)
                pc_meta[name] = (sched, fz, mesh, ce, uk, pg)
        else:
            columns.append(arm)

    tab = Table(
        f"Fig 5 — NUTS grad evals/sec "
        f"(logreg n={num_data} d={dim}, {num_steps} steps/chain"
        + (", per-device batch" if per_device_batch else "") + ")",
        ["batch", *columns],
    )
    # One kernel per arm: the trace and (for pc) the stack-explicit
    # lowering are built once and shared across every batch size in the
    # sweep — only the per-batch-size executors are (re)compiled.
    kernels = {}
    for name, (sched, fz, mesh, ce, uk, pg) in pc_meta.items():
        kern = nuts.make_nuts_kernel(
            target, settings, backend="pc", max_steps=500_000,
            schedule=sched, fuse=fz, mesh=mesh, verify=verify,
            compact_every=ce, use_kernel=uk,
        )
        if pg:
            # Setup-time PGO: trace a profiling run of this variant's own
            # configuration, distill the block-frequency profile, and
            # re-lower through the profile-guided passes.  Profiling is
            # untimed (it happens once, before the sweep) and the
            # optimized kernel stays bit-exact with the baseline.
            from repro.obs import block_profile

            ndev = getattr(mesh, "size", mesh) or 1
            prof_z = 32 if 32 % ndev == 0 else 4 * ndev
            traced = kern.with_options(trace=PGO_TRACE_CAPACITY)
            traced(*nuts.initial_state(target, prof_z, eps=eps, seed=0))
            kern = kern.optimize(block_profile(traced.last_trace))
        kernels[name] = kern
    for arm in ("local", "local_eager"):
        if arm in arms:
            kernels[arm] = nuts.make_nuts_kernel(
                target, settings, backend=arm, max_steps=500_000
            )
    counter = None
    if "unbatched" in arms:
        kernels["unbatched"] = nuts.make_nuts_kernel(
            target, settings, backend="reference"
        )
        # Grad counter for the unbatched arm (same trajectories in
        # expectation): reuse an *unsharded* pc kernel when one is in the
        # sweep anyway (a mesh kernel would reject non-divisible batches).
        counter = next(
            (kernels[n] for n, meta in pc_meta.items() if meta[2] is None),
            None,
        ) or nuts.make_nuts_kernel(target, settings, max_steps=500_000)

    records: list[dict] = []

    def ndev_of(mesh) -> int:
        """Device count of a mesh spec (None | int | 1-D Mesh)."""
        return getattr(mesh, "size", mesh) or 1

    def record(arm: str, z: int, gps: float, **extra) -> float:
        rec = {"arm": arm, "batch": z, "grads_per_sec": gps}
        if arm in pc_meta:
            sched, fz, mesh, ce, uk, pg = pc_meta[arm]
            ndev = ndev_of(mesh)
            rec.update(schedule=sched, fuse=fz, mesh=ndev,
                       per_device_batch=z // ndev,
                       compact_every=ce, use_kernel=uk, pgo=pg)
        rec.update(extra)
        records.append(rec)
        return gps

    inputs_cache: dict[int, tuple] = {}

    def inputs_for(z: int) -> tuple:
        if z not in inputs_cache:
            inputs_cache[z] = nuts.initial_state(target, z, eps=eps, seed=0)
        return inputs_cache[z]

    for z in batch_sizes:
        row = [z]
        for arm in columns:
            # With --per-device-batch, a mesh arm scales its total batch so
            # every device holds `z` lanes (weak scaling); all other arms
            # run `z` total.
            mesh = pc_meta[arm][2] if arm in pc_meta else None
            ndev = ndev_of(mesh)
            z_arm = z * ndev if (per_device_batch and mesh is not None) else z
            if mesh is not None and z_arm % ndev:
                # Batch doesn't divide across this arm's mesh: nan the
                # rendered cell (like the unbatched cap) but record the
                # gap as null — JSON has no NaN, and strict parsers (CI)
                # reject the bare token json.dump would emit.
                row.append(float("nan"))
                record(arm, z_arm, None,
                       skipped="batch does not divide across mesh")
                continue
            theta0, eps_arg, keys = inputs_for(z_arm)
            if arm == "iterative":
                run = iterative.make_batched(target, settings)
                out = run(theta0, eps_arg, keys)
                grads = int(out["grads"].sum())  # warm-up/compile above
                t = best_of(lambda: jax.block_until_ready(
                    run(theta0, eps_arg, keys)["theta"]
                ), repeats)
                row.append(record(arm, z_arm, grads / t))
                continue
            if arm == "unbatched":
                if z_arm > unbatched_cap:
                    row.append(float("nan"))
                    continue
                counter(theta0, eps_arg, keys)
                execs, active = counter.tag_stats["grad"]
                ref = kernels["unbatched"]
                t = best_of(lambda: ref(theta0, eps_arg, keys), 1)
                row.append(record(arm, z_arm, active * gpl / t))
                continue
            kern = kernels[arm]
            kern(theta0, eps_arg, keys)  # warm-up (compile)
            execs, active = kern.tag_stats["grad"]
            extra = {}
            if arm in pc_meta:
                st = kern.scheduler_stats
                extra = {"vm_steps": st.steps, "num_blocks": st.num_blocks,
                         "mean_occupancy": st.mean_occupancy,
                         "mean_lane_occupancy": st.mean_lane_occupancy,
                         "num_devices": st.num_devices,
                         "masked_updates": st.masked_updates}
            t = best_of(lambda: kern(theta0, eps_arg, keys), repeats)
            row.append(record(arm, z_arm, active * gpl / t, **extra))
        tab.add(*row)
    return tab, records


def parse_pc_variants(schedules: str, fuses: str, meshes: str = "none",
                      compacts: str = "none", kernels: str = "off",
                      pgos: str = "off") -> tuple:
    scheds = [s.strip() for s in schedules.split(",") if s.strip()]
    fz_map = {"on": True, "off": False, "true": True, "false": False}

    def parse_onoff(text: str, flag: str) -> list[bool]:
        out = []
        for f in text.split(","):
            f = f.strip().lower()
            if f and f not in fz_map:
                raise SystemExit(f"{flag} values must be on/off, got {f!r}")
            if f:
                out.append(fz_map[f])
        return out

    def parse_none_or_int(text: str, flag: str) -> list:
        out = []
        for m in text.split(","):
            m = m.strip().lower()
            if not m:
                continue
            if m in ("none", "0"):
                out.append(None)
            elif m.isdigit():
                out.append(int(m))
            else:
                raise SystemExit(
                    f"{flag} values must be ints or 'none', got {m!r}"
                )
        return out

    fzs = parse_onoff(fuses, "--fuse")
    ms = parse_none_or_int(meshes, "--mesh")
    ces = parse_none_or_int(compacts, "--compact-every")
    uks = parse_onoff(kernels, "--use-kernel")
    pgs = parse_onoff(pgos, "--pgo")
    if not scheds or not fzs or not ms or not ces or not uks or not pgs:
        raise SystemExit(
            "--schedule, --fuse, --mesh, --compact-every, --use-kernel and "
            "--pgo must each name at least one value (e.g. --schedule "
            "earliest,popular --fuse on,off --mesh none,8 "
            "--compact-every none,1 --use-kernel off --pgo on,off)"
        )
    return tuple(
        (s, f, m, c, k, p)
        for p in pgs for k in uks for c in ces for m in ms
        for f in fzs for s in scheds
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale problem (10k x 100 logreg)")
    ap.add_argument("--batches", default=None,
                    help="comma-separated batch sizes")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--schedule", default="earliest",
                    help="comma list of pc schedules "
                         "(earliest, popular, sweep, lookahead)")
    ap.add_argument("--fuse", default="on",
                    help="comma list of on/off: superblock fusion settings "
                         "for the pc arm")
    ap.add_argument("--mesh", default="none",
                    help="comma list of lane-sharding device counts for the "
                         "pc arm ('none' = unsharded; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--compact-every", default="none",
                    help="comma list of lane-compaction cadences for the pc "
                         "arm ('none' = no compaction; k = permute lanes "
                         "into pc-contiguous order every k dispatches)")
    ap.add_argument("--use-kernel", default="off",
                    help="comma list of on/off: route stack traffic through "
                         "the Pallas masked-scatter kernels (composes with "
                         "--mesh: one shard-local pallas_call per device)")
    ap.add_argument("--pgo", default="off",
                    help="comma list of on/off: re-lower the pc arms "
                         "through the profile-guided pipeline (a setup-time "
                         "traced run collects the block-frequency profile; "
                         "bit-exact, fewer dispatches)")
    ap.add_argument("--per-device-batch", action="store_true",
                    help="treat --batches as per-device: mesh arms scale "
                         "their total batch by the device count "
                         "(weak scaling)")
    ap.add_argument("--verify", action="store_true",
                    help="run the lowered-IR verifier between every "
                         "lowering/fusion pass of the pc arms (sanity at "
                         "benchmark scale; excluded from timed regions)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results (BENCH_fig5.json)")
    args = ap.parse_args(argv)
    if args.full:
        kw: dict = dict(num_data=10_000, dim=100, max_tree_depth=10,
                        num_steps=10)
        batches = [1, 4, 16, 64, 256, 1024]
    else:
        kw = {}
        batches = [1, 4, 16, 64]
    if args.batches:
        batches = [int(b) for b in args.batches.split(",")]
    pc_variants = parse_pc_variants(args.schedule, args.fuse, args.mesh,
                                    args.compact_every, args.use_kernel,
                                    args.pgo)
    tab, records = throughput_sweep(
        batches, repeats=args.repeats, pc_variants=pc_variants,
        per_device_batch=args.per_device_batch, verify=args.verify, **kw
    )
    print(tab.render())
    if args.json:
        payload = {
            "benchmark": "fig5_throughput",
            "unit": "member grad evals / sec",
            "config": {"full": bool(args.full), "batches": batches,
                       "repeats": args.repeats,
                       "per_device_batch": bool(args.per_device_batch),
                       "pc_variants": [list(v) for v in pc_variants], **kw},
            "records": records,
        }
        write_json(args.json, payload)
        print(f"[wrote {args.json}: {len(records)} records]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
